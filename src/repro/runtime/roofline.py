"""Roofline terms from the dry-run's compiled artifacts (TPU v5e targets).

  compute    = FLOPs_per_device / peak_FLOPs          (197 TFLOP/s bf16)
  memory     = HBM bytes_per_device / HBM_bw          (819 GB/s)
  collective = collective bytes_per_device / link_bw  (~50 GB/s/link)

The HLO analyzer reports post-SPMD per-device numbers (verified in tests),
so the brief's `X / (chips * peak)` formula reduces to `X_dev / peak`.
MODEL_FLOPS uses 6*N*D for training and 2*N*D per generated/scored token
for inference (N = active params for MoE).
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchConfig, ShapeConfig

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link (conservatively 1 link used)


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    n = cfg.active_param_count()
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    # decode: one new token per sequence in the batch
    return 2.0 * n * shape.global_batch


def roofline_terms(cfg: ArchConfig, shape: ShapeConfig, hlo_metrics,
                   n_chips: int) -> Dict:
    compute_s = hlo_metrics.flops / PEAK_FLOPS
    memory_s = hlo_metrics.bytes / HBM_BW
    collective_s = hlo_metrics.collective_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    hlo_global = hlo_metrics.flops * n_chips
    bound = max(terms.values())
    model_time = mf / n_chips / PEAK_FLOPS
    return {
        **{k: float(f"{v:.6g}") for k, v in terms.items()},
        "dominant": dominant,
        "model_flops_global": mf,
        "hlo_flops_global": hlo_global,
        "useful_flops_ratio": (mf / hlo_global) if hlo_global else None,
        # fraction of peak achieved at the dominant-term bound, counting
        # only MODEL flops as useful (the score we hillclimb in §Perf) —
        # removing wasted recompute improves this, unlike compute_s/bound
        "roofline_fraction": (model_time / bound) if bound else None,
        "hlo_compute_fraction": (compute_s / bound) if bound else None,
        "step_time_bound_s": bound,
    }


def mpc_roofline_terms(hlo_metrics, n_chips: int) -> Dict:
    compute_s = hlo_metrics.flops / PEAK_FLOPS
    memory_s = hlo_metrics.bytes / HBM_BW
    # party exchanges are inter-pod: data-center network rather than ICI in
    # a real deployment; we report at ICI bw and the benches rescale to
    # LAN/WAN per the paper's methodology.
    collective_s = hlo_metrics.collective_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bound = max(terms.values())
    return {
        **{k: float(f"{v:.6g}") for k, v in terms.items()},
        "dominant": max(terms, key=terms.get),
        "roofline_fraction": (compute_s / bound) if bound else None,
        "step_time_bound_s": bound,
    }
