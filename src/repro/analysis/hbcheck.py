"""hbcheck CLI: the protocol-safety gate.

Runs, in one invocation (see docs/analysis.md):

1. the AST protocol linter (rules R001-R006, ``analysis.lint``) over the
   given paths,
2. the lock-discipline checker for the serving engine/frontend
   (``analysis.locks``),
3. the HLO leakage census on the canonical ResNet ``serve_step``
   lowering (``analysis.taint``; needs jax — skipped with a notice if
   unavailable, forced onto 2 host devices otherwise),
4. ``ruff check`` with the repo's pyproject config, when ruff is
   installed (third-party import/unused-code hygiene shares this gate).

Usage::

    python -m repro.analysis.hbcheck src tests --check

``--check`` makes the exit code a gate: non-zero on any non-baselined
finding, any unmasked collective, or a ruff failure.  Without it the
run only reports.  ``--update-baseline`` rewrites
``tools/hbcheck_baseline.json`` with the current findings (grandfather
them — to be burned down, not grown).
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import shutil
import subprocess
import sys
from typing import List

from repro.analysis import lint as lint_lib
from repro.analysis import locks as locks_lib

DEFAULT_BASELINE = "tools/hbcheck_baseline.json"


def _run_taint() -> dict:
    """Canonical-ResNet leakage census; returns a summary dict or a
    ``{"skipped": reason}`` marker when the environment can't run it."""
    try:
        import jax  # noqa: F401
    except Exception as e:                      # pragma: no cover - no jax
        return {"skipped": f"jax unavailable ({e})"}
    # force a real 2-device party axis before the backend initializes
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=2")
    from repro.analysis import taint as taint_lib
    try:
        return taint_lib.canonical_resnet_census()
    except RuntimeError as e:
        return {"skipped": str(e)}


def _run_ruff(paths: List[str]) -> dict:
    if shutil.which("ruff") is None:
        return {"skipped": "ruff not installed"}
    proc = subprocess.run(["ruff", "check", *paths],
                          capture_output=True, text=True)
    return {"returncode": proc.returncode,
            "output": (proc.stdout + proc.stderr).strip()}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.hbcheck",
        description="HummingBird protocol-safety static analysis gate")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/directories to lint (default: src tests)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero on any non-baselined finding, "
                         "unmasked collective, or ruff failure")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"grandfathered-findings file "
                         f"(default {DEFAULT_BASELINE})")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline with the current findings")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable report on stdout")
    ap.add_argument("--no-taint", action="store_true",
                    help="skip the HLO leakage census (compiles the "
                         "canonical ResNet serve step)")
    ap.add_argument("--no-locks", action="store_true",
                    help="skip the serve-engine lock-discipline check")
    ap.add_argument("--no-ruff", action="store_true",
                    help="skip the ruff hygiene pass")
    args = ap.parse_args(argv)
    paths = args.paths or ["src", "tests"]

    findings = lint_lib.lint_paths(paths)
    if not args.no_locks:
        findings.extend(locks_lib.check_paths("."))
    findings.sort(key=lambda f: (f.file, f.line, f.rule))

    if args.update_baseline:
        lint_lib.save_baseline(args.baseline, findings)
        print(f"baseline rewritten: {len(findings)} entries -> "
              f"{args.baseline}")
        return 0

    baseline = lint_lib.load_baseline(args.baseline)
    new = [f for f in findings if f.key() not in baseline]
    baselined = len(findings) - len(new)

    taint = {"skipped": "--no-taint"} if args.no_taint else _run_taint()
    ruff = {"skipped": "--no-ruff"} if args.no_ruff else _run_ruff(paths)

    unmasked = taint.get("unmasked_collectives")
    taint_bad = (unmasked not in (None, 0)
                 or taint.get("cross_check_ok") is False)
    ruff_bad = ruff.get("returncode", 0) != 0
    failed = bool(new) or taint_bad or ruff_bad

    if args.as_json:
        print(json.dumps({
            "findings": [vars(f) for f in new],
            "baselined": baselined,
            "taint": taint,
            "ruff": ruff,
            "ok": not failed,
        }, indent=1))
    else:
        for f in new:
            print(f)
        if ruff_bad:
            print(ruff["output"])
        status = []
        status.append(f"lint+locks: {len(new)} finding(s)"
                      + (f" ({baselined} baselined)" if baselined else ""))
        if "skipped" in taint:
            status.append(f"taint census: skipped ({taint['skipped']})")
        else:
            status.append(
                f"taint census: {taint['collectives']} collectives, "
                f"{unmasked} unmasked, cross-check "
                f"{'ok' if taint.get('cross_check_ok') else 'FAILED'}")
        if "skipped" in ruff:
            status.append(f"ruff: skipped ({ruff['skipped']})")
        else:
            status.append("ruff: " + ("ok" if not ruff_bad else "FAILED"))
        print("hbcheck: " + "; ".join(status))

    if args.check and failed:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
