"""seamless-m4t-medium [audio]: enc-dec, 12L d_model=1024 16H d_ff=4096
vocab=256206; audio frontend is a stub providing precomputed frame
embeddings per the brief.  [arXiv:2308.11596]"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="encdec", n_layers=12, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=256206, n_enc_layers=12,
    act="relu", gated_mlp=False, norm="layernorm", frontend="audio",
    rope_theta=0.0,
)
