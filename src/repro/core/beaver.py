"""Trusted-third-party Beaver triple provider.

The paper's evaluation (§5.1) assumes triples are generated offline by a TTP
(or stored pre-generated), so triple generation is excluded from
communication/latency accounting.  We generate them deterministically from a
PRG key; shares carry the leading party dimension so they can be fed into
both the sim backend and (party-sharded) into the mesh backend.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from . import ring, shares

_U32 = jnp.uint32


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ArithTriple:
    """Additive shares of (a, b, c = a*b) on Z/2^64, party dim leading."""

    a: ring.Ring64
    b: ring.Ring64
    c: ring.Ring64

    def tree_flatten(self):
        return (self.a, self.b, self.c), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BinTriple:
    """XOR shares of packed-word (a, b, c = a & b), party dim leading."""

    a: jax.Array
    b: jax.Array
    c: jax.Array

    def tree_flatten(self):
        return (self.a, self.b, self.c), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def gen_arith(key, shape, n_parties: int = 2) -> ArithTriple:
    ka, kb, ksa, ksb, ksc = jax.random.split(key, 5)
    a = ring.uniform(ka, shape)
    b = ring.uniform(kb, shape)
    c = ring.mul(a, b)
    return ArithTriple(
        shares.share(ksa, a, n_parties),
        shares.share(ksb, b, n_parties),
        shares.share(ksc, c, n_parties),
    )


def gen_bin(key, shape, n_parties: int = 2) -> BinTriple:
    ka, kb, ksa, ksb, ksc = jax.random.split(key, 5)
    a = jax.random.bits(ka, shape, dtype=_U32)
    b = jax.random.bits(kb, shape, dtype=_U32)
    c = a & b
    return BinTriple(
        shares.xor_share_packed(ksa, a, n_parties),
        shares.xor_share_packed(ksb, b, n_parties),
        shares.xor_share_packed(ksc, c, n_parties),
    )


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ReluTriples:
    """Everything one approximate-ReLU evaluation consumes, pre-generated.

    For E elements and a w-bit reduced ring (W = ceil(E/32) packed words,
    L = ceil(log2(w)) Kogge-Stone levels):
      - bin_init:   (P, w, W) AND triple for the initial generate plane
      - bin_levels: (L, P, 2w, W) one batched AND triple per level
      - b2a:        (P, E) arithmetic triple for the sign-bit B2A
      - mult:       (P, E) arithmetic triple for the final x * DReLU(x)
    """

    bin_init: BinTriple
    bin_levels: BinTriple  # leading L axis on each member
    b2a: ArithTriple
    mult: ArithTriple

    def tree_flatten(self):
        return (self.bin_init, self.bin_levels, self.b2a, self.mult), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


def n_levels(w: int) -> int:
    return max(0, math.ceil(math.log2(w))) if w > 1 else 0


def gen_relu_triples(key, n_elements: int, w: int, n_parties: int = 2,
                     cone: bool = False) -> ReluTriples:
    """cone=True sizes the AND triples to the MSB-cone-pruned circuit
    (bin_levels becomes a per-level tuple — sizes are ragged)."""
    W = shares.packed_words(n_elements)
    L = n_levels(w)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if cone and w > 1:
        from . import gmw  # late: gmw imports beaver
        init_pos, level_sets = gmw.cone_sets(w)
        bin_init = gen_bin(k1, (len(init_pos), W), n_parties)
        bin_levels = tuple(
            gen_bin(k, (2 * max(len(pos), 1), W), n_parties)
            for k, pos in zip(jax.random.split(k2, max(L, 1)), level_sets))
    else:
        bin_init = gen_bin(k1, (w, W), n_parties)
        levels = [gen_bin(k, (2 * w, W), n_parties)
                  for k in jax.random.split(k2, max(L, 1))]
        bin_levels = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs, axis=0), *levels)
    b2a = gen_arith(k3, (n_elements,), n_parties)
    mult = gen_arith(k4, (n_elements,), n_parties)
    return ReluTriples(bin_init, bin_levels, b2a, mult)
